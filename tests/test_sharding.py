"""Distribution: pspec rules, FSDP constraints, micro-mesh train/serve
compile with sane collectives (subprocess with 8 fake devices)."""
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.models.params import make_pspec, DEFAULT_RULES

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_make_pspec_basic():
    assert make_pspec(("embed", "mlp"), DEFAULT_RULES) == P("data", "model")
    assert make_pspec(("vocab", "embed"), DEFAULT_RULES) == P("model", "data")
    assert make_pspec(("periods", "embed", "heads", "null"),
                      DEFAULT_RULES) == P(None, "data", "model", None)


def test_make_pspec_no_axis_reuse():
    # expert and mlp both map to model; first wins
    assert make_pspec(("expert", "embed", "mlp"),
                      DEFAULT_RULES) == P("model", "data", None)


def test_make_pspec_multi_axis_fsdp():
    rules = dict(DEFAULT_RULES, embed=("pod", "data"))
    assert make_pspec(("embed", "mlp"), rules) == P(("pod", "data"), "model")
    # pod used by batch already -> embed falls back to data only
    rules2 = dict(rules, batch=("pod", "data"))
    assert make_pspec(("batch", "embed"), rules2) == \
        P(("pod", "data"), None)


MICRO = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, re
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config, with_overrides
from repro.configs.base import TrainConfig
from repro.models.policy import BackbonePolicy
from repro.models.params import set_fsdp_axes
from repro.distributed import sharding as shd
from repro.rl.learner import make_lm_train_step
from repro.rl import actor
from repro.data.buffer import abstract_batch
from repro.launch.hlo_analysis import analyze

set_fsdp_axes(("data",))
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = shd.make_rules(mesh)

for arch in ("qwen3-0.6b", "llama4-maverick-400b-a17b", "jamba-v0.1-52b"):
    cfg = with_overrides(get_smoke_config(arch), num_layers=2)
    pol = BackbonePolicy(cfg, tp=4, kernel="chunked")
    state = shd.abstract_train_state(pol, "float32")
    state_sh = shd.named(mesh, shd.train_state_pspecs(pol, rules))
    B, T = 16, 64
    batch = abstract_batch(cfg, B, T)
    batch_sh = shd.named(mesh, {k: P(*(["data"] + [None]*(len(v.shape)-1)))
                                for k, v in batch.items()})
    step = make_lm_train_step(pol, TrainConfig(), loss_chunk=16)
    with mesh:
        c = jax.jit(step, in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None)).lower(state, batch).compile()
    an = analyze(c.as_text(), 8)
    assert an["flops"] > 0 and an["collective_bytes"] > 0
    # no catastrophic batch gather: collective bytes must stay well under
    # the total bytes moved
    assert an["collective_bytes"] < 0.5 * an["bytes"], (
        arch, an["collective_bytes"], an["bytes"])
    print(arch, "TRAIN_OK")

# decode on the micro mesh
cfg = with_overrides(get_smoke_config("qwen3-0.6b"), num_layers=2)
pol = BackbonePolicy(cfg, tp=4, kernel="chunked")
params = pol.abstract()
params_sh = shd.named(mesh, pol.pspecs(rules))
caches = shd.abstract_caches(cfg, 4, 8, 128)
caches_sh = shd.named(mesh, shd.cache_pspecs(cfg, rules))
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
key = jax.ShapeDtypeStruct((2,), jnp.uint32)
sv = actor.make_serve_step(pol)
from jax.sharding import NamedSharding
with mesh:
    c = jax.jit(sv, in_shardings=(params_sh,
                                  NamedSharding(mesh, P("data", None)),
                                  caches_sh, None),
                out_shardings=(None, None, caches_sh),
                donate_argnums=(2,)).lower(params, tok, caches, key).compile()
print("SERVE_OK")
"""


@pytest.mark.multi_device
def test_micro_mesh_compiles():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MICRO], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=560)
    assert out.stdout.count("TRAIN_OK") == 3, out.stderr[-3000:]
    assert "SERVE_OK" in out.stdout, out.stderr[-3000:]
