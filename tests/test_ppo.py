"""PPO correctness: distributions, loss properties, learning on Ocean."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.rl import distributions as D
from repro.rl import ppo


def test_multidiscrete_logprob_sums_components():
    nvec = (3, 4)
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 7))
    a = jnp.stack([jnp.zeros(5, jnp.int32), jnp.ones(5, jnp.int32)], -1)
    lp = D.log_prob(logits, a, nvec)
    lp0 = jax.nn.log_softmax(logits[:, :3])[:, 0]
    lp1 = jax.nn.log_softmax(logits[:, 3:])[:, 1]
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp0 + lp1),
                               rtol=1e-6)


def test_entropy_uniform_is_log_n():
    nvec = (4,)
    ent = D.entropy(jnp.zeros((3, 4)), nvec)
    np.testing.assert_allclose(np.asarray(ent), np.log(4), rtol=1e-6)


def test_sample_distribution():
    nvec = (2,)
    logits = jnp.asarray([[0.0, jnp.log(3.0)]])   # p = [0.25, 0.75]
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    s = jax.vmap(lambda k: D.sample(k, logits, nvec))(keys)
    frac1 = float(jnp.mean(s == 1))
    assert 0.70 < frac1 < 0.80


def test_ppo_terms_zero_at_ratio_one():
    tcfg = TrainConfig()
    lp = jnp.asarray([-1.0, -2.0, -0.5])
    adv = jnp.asarray([1.0, -1.0, 0.5])
    pg, kl, cf = ppo.ppo_terms(lp, lp, adv, tcfg)
    np.testing.assert_allclose(float(pg), -float(jnp.mean(adv)), rtol=1e-6)
    assert abs(float(kl)) < 1e-6 and float(cf) == 0.0


def test_ppo_clipping_engages():
    tcfg = TrainConfig(clip_coef=0.2)
    old = jnp.zeros((4,))
    new = jnp.asarray([1.0, 1.0, -1.0, -1.0])    # big ratios
    adv = jnp.asarray([1.0, -1.0, 1.0, -1.0])
    pg, kl, cf = ppo.ppo_terms(new, old, adv, tcfg)
    assert float(cf) == 1.0


def test_value_loss_clipped_vs_unclipped():
    tcfg = TrainConfig(vf_clip=0.1)
    old_v = jnp.zeros((4,))
    new_v = jnp.asarray([1.0, 1.0, 1.0, 1.0])    # moved far from old
    ret = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    vl = ppo.value_loss(new_v, old_v, ret, tcfg)
    # clipped prediction 0.1 is far from return 1 -> loss stays high
    assert float(vl) >= 0.5 * (0.9 ** 2) - 1e-6


def test_chunked_token_loss_matches_unchunked():
    """Chunked vocab loss == direct computation on small shapes."""
    from repro.configs import get_smoke_config, with_overrides
    from repro.models.policy import BackbonePolicy
    from repro.models import transformer as tr
    cfg = with_overrides(get_smoke_config("qwen3-0.6b"), num_layers=2,
                         dtype="float32", param_dtype="float32")
    pol = BackbonePolicy(cfg, tp=1, kernel="ref")
    params = pol.init(jax.random.PRNGKey(0), jnp.float32)
    B, T = 2, 16
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    hidden, _ = tr.forward(params["backbone"], {"tokens": toks}, cfg, 1,
                           kernel="ref")
    actions = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                                 cfg.vocab_size)
    olp = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (B, T)))
    adv = jax.random.normal(jax.random.fold_in(key, 3), (B, T))
    tcfg = TrainConfig()
    pg8, ent8, kl8, cf8 = ppo.chunked_token_loss(
        params["backbone"], hidden, actions, olp, adv, cfg, tcfg, chunk=8)
    pg16, ent16, kl16, cf16 = ppo.chunked_token_loss(
        params["backbone"], hidden, actions, olp, adv, cfg, tcfg, chunk=16)
    np.testing.assert_allclose(float(pg8), float(pg16), rtol=1e-5)
    np.testing.assert_allclose(float(ent8), float(ent16), rtol=1e-5)
    np.testing.assert_allclose(float(kl8), float(kl16), rtol=1e-5)


def test_adamw_decreases_quadratic():
    from repro.optim import adamw
    w = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw.init(w)
    for _ in range(200):
        g = {"w": 2 * w["w"]}
        w, st, _ = adamw.update(g, st, w, lr=0.1)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.5


def test_grad_clip():
    from repro.optim import adamw
    w = {"w": jnp.ones((3,))}
    st = adamw.init(w)
    g = {"w": jnp.full((3,), 1e6)}
    _, _, stats = adamw.update(g, st, w, lr=0.1, max_grad_norm=1.0)
    assert float(stats["grad_norm"]) > 1e5   # reported pre-clip


@pytest.mark.slow
def test_ppo_solves_bandit():
    from repro.envs.ocean import Bandit
    from repro.rl.trainer import Trainer
    tr = Trainer(Bandit(), TrainConfig(num_envs=64, unroll_length=64,
                                       update_epochs=4, num_minibatches=4,
                                       learning_rate=1e-3, gamma=0.95),
                 hidden=64, kernel_mode="ref")
    m = tr.train(120_000, target_score=0.9)
    assert m["score"] >= 0.9, m


@pytest.mark.slow
def test_ppo_solves_memory_only_with_recurrence():
    """The paper's point: Memory is unsolvable without the LSTM sandwich."""
    from repro.envs.ocean import Memory
    from repro.rl.trainer import Trainer
    tcfg = TrainConfig(num_envs=64, unroll_length=64, update_epochs=4,
                       num_minibatches=4, learning_rate=1e-3, gamma=0.95)
    rec = Trainer(Memory(), tcfg, hidden=64, recurrent=True,
                  kernel_mode="ref").train(400_000, target_score=0.9)
    assert rec["score"] >= 0.9, rec
    flat = Trainer(Memory(), tcfg, hidden=64, recurrent=False,
                   kernel_mode="ref").train(150_000, target_score=0.95)
    assert flat["score"] < 0.9, flat


def test_gaussian_distribution():
    """Continuous-action support (the paper's §8 limitation, implemented)."""
    out = jnp.asarray([[1.0, -2.0, 0.0, 0.0]])   # mean=(1,-2), log_std=0
    lp = D.gaussian_log_prob(out, jnp.asarray([[1.0, -2.0]]), 2)
    # at the mean: logp = -0.5*log(2*pi)*2
    np.testing.assert_allclose(float(lp[0]), -np.log(2 * np.pi), rtol=1e-6)
    ent = D.gaussian_entropy(out, 2)
    np.testing.assert_allclose(float(ent[0]), np.log(2 * np.pi * np.e),
                               rtol=1e-6)
    keys = jax.random.split(jax.random.PRNGKey(0), 2000)
    s = jax.vmap(lambda k: D.gaussian_sample(k, out, 2))(keys)
    np.testing.assert_allclose(np.asarray(s.mean(0))[0], [1.0, -2.0],
                               atol=0.1)


@pytest.mark.slow
def test_ppo_solves_continuous_env():
    """Gaussian PPO end-to-end through emulation on a Box action space."""
    from repro.envs.ocean import Continuous
    from repro.rl.trainer import Trainer
    tr = Trainer(Continuous(), TrainConfig(num_envs=64, unroll_length=64,
                                           update_epochs=4, num_minibatches=4,
                                           learning_rate=1e-3, gamma=0.95),
                 hidden=64, kernel_mode="ref")
    m = tr.train(400_000, target_score=0.9)
    assert m["score"] >= 0.9, m
