"""Kernel dispatch subsystem: registry resolution, overrides, autotune,
interpret-vs-ref parity for every registered op, and a regression test for
the reused-named-scope bug class."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import compat, dispatch, ops, ref


@pytest.fixture(autouse=True)
def _clean_kernel_env(monkeypatch):
    """Resolution must not depend on overrides set in the developer's shell."""
    monkeypatch.delenv(dispatch.ENV_GLOBAL, raising=False)
    for op in dispatch.OPS:
        monkeypatch.delenv(dispatch.env_var(op), raising=False)


# -- registry -----------------------------------------------------------------

def test_all_ops_registered():
    assert set(dispatch.OPS) <= set(dispatch.ops())
    for op in dispatch.OPS:
        impls = dispatch.implementations(op)
        assert dispatch.REF in impls, op
        assert dispatch.INTERPRET in impls, op
        assert dispatch.PALLAS in impls, op


def test_platform_resolution():
    for op in dispatch.OPS:
        assert dispatch.resolve(op, plat="cpu") == dispatch.REF
        assert dispatch.resolve(op, plat="tpu") == dispatch.PALLAS


def test_compiled_pallas_unavailable_off_tpu():
    for op in dispatch.OPS:
        assert dispatch.PALLAS not in dispatch.available(op, plat="cpu")
        assert dispatch.PALLAS in dispatch.available(op, plat="tpu")
        assert dispatch.REF in dispatch.available(op, plat="cpu")


def test_interpret_alias():
    assert dispatch.resolve("gae", mode="interpret") == dispatch.INTERPRET
    assert dispatch.resolve("gae", mode="pallas_interpret") == \
        dispatch.INTERPRET


def test_unknown_op_and_impl_raise():
    with pytest.raises(KeyError):
        dispatch.resolve("not_an_op")
    with pytest.raises(KeyError):
        dispatch.resolve("pack", mode="chunked")   # pack has no chunked


def test_explicit_mode_beats_env(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "chunked")
    assert dispatch.resolve("flash_attention", mode="ref") == dispatch.REF


def test_per_op_env_beats_global(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "interpret")
    monkeypatch.setenv(dispatch.env_var("gae"), "ref")
    assert dispatch.resolve("gae") == dispatch.REF
    assert dispatch.resolve("flash_attention") == dispatch.INTERPRET


def test_per_op_env_unknown_impl_raises(monkeypatch):
    monkeypatch.setenv(dispatch.env_var("pack"), "chunked")
    with pytest.raises(KeyError):
        dispatch.resolve("pack")


def test_global_env_lenient_fallback(monkeypatch):
    # "chunked" isn't registered for pack: global override skips it
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "chunked")
    assert dispatch.resolve("flash_attention", plat="cpu") == dispatch.CHUNKED
    assert dispatch.resolve("pack", plat="cpu") == dispatch.REF


def test_using_scope(monkeypatch):
    assert dispatch.resolve("flash_attention", plat="cpu") == dispatch.REF
    with dispatch.using("chunked"):
        assert dispatch.resolve("flash_attention", plat="cpu") == \
            dispatch.CHUNKED
        assert dispatch.resolve("pack", plat="cpu") == dispatch.REF  # lenient
        with dispatch.using("ref"):   # reentrant, innermost wins
            assert dispatch.resolve("flash_attention", plat="cpu") == \
                dispatch.REF
        assert dispatch.resolve("flash_attention", plat="cpu") == \
            dispatch.CHUNKED
    assert dispatch.resolve("flash_attention", plat="cpu") == dispatch.REF


def test_scope_beats_env(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_GLOBAL, "ref")
    with dispatch.using("chunked"):
        assert dispatch.resolve("flash_attention", plat="cpu") == \
            dispatch.CHUNKED


# -- compat shim --------------------------------------------------------------

def test_compiler_params_resolves_some_spelling():
    cp = compat.compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert compat.HAS_PALLAS
    assert cp is not None
    assert tuple(cp.dimension_semantics) == ("parallel", "arbitrary")


def test_compiler_params_drops_unknown_kwargs():
    cp = compat.compiler_params(
        dimension_semantics=("arbitrary",),
        definitely_not_a_real_field_xyz=1)
    assert cp is not None


def test_jax_version_is_tuple_of_ints():
    v = compat.jax_version()
    assert len(v) >= 2 and all(isinstance(p, int) for p in v)


# -- interpret-mode parity for every registered op ----------------------------

def _parity_args(op):
    k0 = jax.random.PRNGKey(0)
    r = lambda i, shape, scale=1.0: (
        jax.random.normal(jax.random.fold_in(k0, i), shape, jnp.float32)
        * scale)
    if op == "flash_attention":
        q, k, v = r(1, (1, 32, 2, 16)), r(2, (1, 32, 2, 16)), \
            r(3, (1, 32, 2, 16))
        return (q, k, v), dict(causal=True, block_q=16, block_k=16)
    if op == "flash_decode":
        q, k, v = r(1, (2, 4, 16)), r(2, (2, 32, 2, 16)), r(3, (2, 32, 2, 16))
        return (q, k, v, jnp.asarray(17, jnp.int32)), dict(block_s=16)
    if op == "quant_matmul":
        x = r(1, (16, 32))
        wq = jax.random.randint(jax.random.fold_in(k0, 2), (32, 128),
                                -127, 128, jnp.int32).astype(jnp.int8)
        s = jnp.abs(r(3, (128,))) * 0.02
        return (x, wq, s), {}
    if op == "gae":
        return (r(1, (4, 32)), r(2, (4, 32)),
                jax.random.bernoulli(jax.random.fold_in(k0, 3), 0.2, (4, 32)),
                r(4, (4,)), 0.99, 0.95), dict(block_t=8)
    if op == "ssd":
        x = r(1, (1, 32, 2, 8), 0.5)
        dt = jax.nn.softplus(r(2, (1, 32, 2)))
        A = -jnp.exp(r(3, (2,), 0.3))
        B_ = r(4, (1, 32, 2, 8), 0.5)
        C = r(5, (1, 32, 2, 8), 0.5)
        return (x, dt, A, B_, C), dict(chunk=8)
    if op == "pack":
        leaves = [jax.random.randint(jax.random.fold_in(k0, i), (4, n),
                                     0, 256, jnp.int32).astype(jnp.uint8)
                  for i, n in enumerate((3, 7, 16))]
        return (leaves,), {}
    raise AssertionError(op)


@pytest.mark.parametrize("op", dispatch.OPS)
def test_interpret_matches_ref(op):
    """Every registered op: real Pallas body (interpreted) == jnp oracle."""
    args, kw = _parity_args(op)
    want = dispatch.call(op, *args, mode="ref", **kw)
    got = dispatch.call(op, *args, mode="interpret", **kw)
    jax.tree.map(
        lambda g, w: np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=1e-4, rtol=1e-4),
        got, want)


# -- autotune -----------------------------------------------------------------

def test_autotune_picks_winner_and_feeds_auto_dispatch():
    args, kw = _parity_args("gae")
    try:
        results, best = dispatch.autotune(
            "gae", *args, impls=(dispatch.REF, dispatch.INTERPRET),
            iters=2, **kw)
        assert set(results) == {dispatch.REF, dispatch.INTERPRET}
        assert best in results
        assert all(r > 0 for r in results.values())
        # the cached winner now drives auto dispatch on this platform
        assert dispatch.resolve("gae") == best
    finally:
        dispatch.clear_autotune()
    assert dispatch.resolve("gae", plat="cpu") == dispatch.REF


def test_autotune_skips_broken_impls():
    args, kw = _parity_args("pack")
    # compiled pallas can't run on CPU — autotune must skip it, not raise
    results, best = dispatch.autotune(
        "pack", *args, impls=(dispatch.REF, dispatch.PALLAS), iters=1, **kw)
    assert best == dispatch.REF
    dispatch.clear_autotune()


# -- ops-level round trip through the public wrappers -------------------------

def test_ops_mode_none_equals_auto():
    args, kw = _parity_args("gae")
    a = ops.gae(*args, mode=None, **kw)
    b = ops.gae(*args, mode="auto", **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_chunked_matches_ref_flash_attention():
    (q, k, v), kw = _parity_args("flash_attention")
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v, mode="chunked")),
        np.asarray(ref.flash_attention(q, k, v)), atol=2e-5, rtol=2e-5)


# -- named-scope reuse regression (the mlp_apply/moe_apply seed bug) ----------

def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny", family="dense", num_layers=1,
                       d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                       vocab_size=64, head_dim=8, dtype="float32",
                       param_dtype="float32")


def test_mlp_apply_single_call_enters_scope_twice():
    """mlp_apply enters its named scope twice per call; a reused context
    manager raises AttributeError on the second __enter__."""
    from repro.models import layers as L
    cfg = _tiny_cfg()
    params = {
        "wi": jnp.ones((cfg.d_model, 2 * cfg.d_ff), jnp.float32) * 0.01,
        "wo": jnp.ones((cfg.d_ff, cfg.d_model), jnp.float32) * 0.01,
    }
    x = jnp.ones((2, cfg.d_model), jnp.float32)
    out = L.mlp_apply(params, x, cfg)
    assert out.shape == (2, cfg.d_model)


def test_same_model_entered_twice_in_one_trace():
    """The bug class: tracing a module twice in one jit trace must not
    crash on reused context managers anywhere in the stack."""
    from repro.models import layers as L
    cfg = _tiny_cfg()
    params = {
        "wi": jnp.ones((cfg.d_model, 2 * cfg.d_ff), jnp.float32) * 0.01,
        "wo": jnp.ones((cfg.d_ff, cfg.d_model), jnp.float32) * 0.01,
    }

    @jax.jit
    def twice(p, x):
        return L.mlp_apply(p, x, cfg) + L.mlp_apply(p, x, cfg)

    out = twice(params, jnp.ones((2, cfg.d_model), jnp.float32))
    assert out.shape == (2, cfg.d_model)


def test_moe_apply_entered_twice_in_one_trace():
    from repro.models import moe as moe_mod
    from repro.configs.base import ModelConfig
    from repro.models.params import init_params
    cfg = ModelConfig(name="tiny-moe", family="moe", num_layers=1,
                      d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                      vocab_size=64, head_dim=8, num_experts=4, top_k=2,
                      dtype="float32", param_dtype="float32")
    params = init_params(moe_mod.moe_spec(cfg), jax.random.PRNGKey(0),
                        jnp.float32)

    @jax.jit
    def twice(p, x):
        y1, a1 = moe_mod.moe_apply(p, x, cfg)
        y2, a2 = moe_mod.moe_apply(p, x, cfg)
        return y1 + y2, a1 + a2

    y, aux = twice(params, jnp.ones((1, 8, cfg.d_model), jnp.float32))
    assert y.shape == (1, 8, cfg.d_model)
    assert np.isfinite(float(aux))
