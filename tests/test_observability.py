"""Observability PR: cross-process trace propagation (worker span files,
clock-offset rebasing, killed-worker merge tolerance, the export-trace
acceptance on a real async run), the live monitoring endpoints over a real
socket (/metrics, /healthz flipping on a killed actor, /spans), the
benchwatch perf-regression sentinel (baseline, gating, fingerprint
isolation), and the BLOCKING-NO-TIMEOUT lint extension to accept loops."""
import glob
import json
import os
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax

from repro import telemetry
from repro.telemetry import __main__ as tcli
from repro.telemetry import benchwatch, traceprop
from repro.telemetry import spans as tspans
from repro.telemetry.http import MetricsServer, collect_health
from repro.telemetry.registry import registry

RECV_T = 30.0
HTTP_T = 5.0


@pytest.fixture(autouse=True)
def _clean_telemetry():
    telemetry.disable()
    registry().reset()
    yield
    telemetry.disable()
    registry().reset()


def _get(url, timeout=HTTP_T):
    """(status, body bytes) — non-2xx statuses returned, not raised."""
    try:
        with urllib.request.urlopen(url, timeout=timeout) as r:
            return r.status, r.read()
    except urllib.error.HTTPError as e:
        return e.code, e.read()


# ---------------------------------------------------------------------------
# clock offset + worker file plumbing

def test_clock_offset_maps_monotonic_onto_wall_clock():
    off = tspans.clock_offset_ns()
    # monotonic + offset ≈ wall, within scheduling noise
    assert abs(time.monotonic_ns() + off - time.time_ns()) < 50_000_000
    # and the estimate is stable call-to-call (median of 5 samples)
    assert abs(tspans.clock_offset_ns() - off) < 50_000_000


def test_traceprop_current_none_without_run_dir():
    assert traceprop.current() is None       # tracing off
    telemetry.enable()                       # ring-only: nowhere to flush
    assert traceprop.current() is None
    telemetry.disable()


def test_traceprop_current_snapshots_tracer(tmp_path):
    telemetry.enable(run_dir=str(tmp_path))
    cfg = traceprop.current()
    assert cfg is not None and cfg.run_dir == str(tmp_path)
    assert cfg.trace_id == tspans.get_tracer().trace_id
    # the parent's own file carries an eagerly-written meta header
    files = traceprop.load_run_spans(str(tmp_path))
    assert len(files) == 1
    meta, recs = files[0]
    assert meta["pid"] == os.getpid() and meta["role"] == "main"
    assert meta["trace_id"] == cfg.trace_id and recs == []


def test_merge_tolerates_torn_tail_and_missing_meta(tmp_path):
    run_dir = str(tmp_path)
    # a healthy worker file
    with open(os.path.join(run_dir, "spans-111.jsonl"), "w") as f:
        f.write(json.dumps({"kind": "meta", "schema": 1, "trace_id": "t",
                            "pid": 111, "role": "host-worker-0",
                            "clock_offset_ns": 1000}) + "\n")
        f.write(json.dumps({"name": "worker.step", "ts_ns": 50, "dur_ns": 10,
                            "pid": 111, "tid": 1, "depth": 0,
                            "parent": ""}) + "\n")
        f.write('{"name": "worker.step", "ts_ns": 60, "dur')  # SIGKILL tear
    # a meta-less file from a pre-handshake writer
    with open(os.path.join(run_dir, "spans-222.jsonl"), "w") as f:
        f.write(json.dumps({"name": "worker.reset", "ts_ns": 30, "dur_ns": 5,
                            "pid": 222, "tid": 2, "depth": 0,
                            "parent": ""}) + "\n")
    recs = traceprop.merged_records(run_dir)
    assert [r["name"] for r in recs] == ["worker.reset", "worker.step"]
    by_pid = {r["pid"]: r for r in recs}
    assert by_pid[111]["ts_ns"] == 1050      # offset applied
    assert by_pid[222]["ts_ns"] == 30        # no meta -> offset 0
    assert by_pid[222]["role"] == "pid-222"  # role recovered from filename
    trace = traceprop.merge_chrome_trace(run_dir)
    lanes = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M"}
    assert lanes == {111: "host-worker-0", 222: "pid-222"}


# ---------------------------------------------------------------------------
# proc host pool: real spawn workers on one merged timeline

@pytest.mark.timeout(300)
def test_proc_pool_workers_merge_onto_one_timeline(tmp_path):
    """The tentpole acceptance for the host tier: a traced proc-pool run
    leaves one spans file per worker pid; the merge puts parent spawn/recv
    and worker step/reset on one wall-aligned timeline — and a worker
    SIGKILLed after its last flush (plus a planted torn tail) degrades the
    merge to 'skip the damage', never an error."""
    from repro.bridge import wrap
    from repro.envs.ocean_host import HostBandit
    run_dir = str(tmp_path)
    telemetry.enable(run_dir=run_dir)
    v = wrap(HostBandit, num_envs=2, backend="proc")
    try:
        obs = v.reset(timeout=RECV_T)
        for _ in range(3):
            obs, _r, _d, _i = v.step(np.zeros((len(obs), 1), np.int32),
                                     timeout=RECV_T)
        time.sleep(0.3)                 # cross the workers' flush cadence
        for _ in range(2):              # post-gap ops trigger the flush
            obs, _r, _d, _i = v.step(np.zeros((len(obs), 1), np.int32),
                                     timeout=RECV_T)
        live = v.pool.liveness()
        assert live["dead"] == []
        assert all(b > 0 for b in live["last_beat_ns"])
        v.pool._procs[1].kill()         # SIGKILL: no finally-flush
        v.pool._procs[1].join(timeout=10)
    finally:
        v.close()
    telemetry.flush()

    files = sorted(os.path.basename(p) for p in
                   glob.glob(os.path.join(run_dir, "spans*.jsonl")))
    assert len(files) == 3 and "spans.jsonl" in files
    # plant a torn tail on the killed worker's file
    worker_files = [f for f in files if f != "spans.jsonl"]
    with open(os.path.join(run_dir, worker_files[-1]), "a") as f:
        f.write('{"name": "worker.step", "ts_ns": 1, "d')

    trace = traceprop.merge_chrome_trace(run_dir)
    xs = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    lanes = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M"}
    assert len({e["pid"] for e in xs}) >= 3          # learner + 2 workers
    assert len(lanes) >= 3
    roles = set(lanes.values())
    assert "main" in roles
    assert {"host-worker-0", "host-worker-1"} <= roles
    assert len(trace["otherData"]["trace_ids"]) == 1  # one shared trace id

    # clock-offset monotonicity: after rebasing, no worker span starts
    # before the parent began spawning it (1 ms slack for offset noise)
    recs = traceprop.merged_records(run_dir)
    spawn = [r for r in recs if r["name"] == "host.spawn"]
    worker = [r for r in recs if r["role"].startswith("host-worker")]
    assert spawn and worker
    assert min(r["ts_ns"] for r in worker) >= spawn[0]["ts_ns"] - 1_000_000


# ---------------------------------------------------------------------------
# async tier: the export-trace acceptance

def _async_engine(tmpdir=None, **overrides):
    from repro.configs.ocean import ocean_tcfg
    from repro.envs.ocean import Bandit
    from repro.rl.engine import TrainEngine
    from repro.rl.trainer import ocean_policy_stack
    em, dist, policy = ocean_policy_stack(Bandit(), hidden=32,
                                          recurrent=False, conv=None)
    kw = dict(num_envs=8, unroll_length=8, num_actors=2, checkpoint_every=0)
    kw.update(overrides)
    tcfg = ocean_tcfg("bandit", **kw)
    return TrainEngine(em, policy, tcfg, dist, key=jax.random.PRNGKey(0),
                       backend="async",
                       checkpoint_dir=str(tmpdir) if tmpdir else None)


@pytest.mark.timeout(600)
def test_async_export_trace_merges_learner_and_actor_lanes(tmp_path):
    """ISSUE acceptance: export-trace on an async run dir yields ONE Chrome
    trace where the learner and >= 2 actor pids appear in distinct lanes."""
    run_dir = str(tmp_path / "run")
    telemetry.enable(run_dir=run_dir)
    spu = 8 * 8
    eng = _async_engine()
    try:
        hist, _ = eng.run(total_steps=spu * 3)
        assert len(hist) == 3
    finally:
        eng.close()                      # actors flush spans in finally
    telemetry.flush()

    out = str(tmp_path / "merged_trace.json")
    assert tcli.main(["export-trace", run_dir, "--out", out]) == 0
    with open(out) as f:
        trace = json.load(f)
    xs = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    lanes = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M"}
    assert len({e["pid"] for e in xs}) >= 3
    roles = set(lanes.values())
    assert "main" in roles and {"actor-0", "actor-1"} <= roles
    names = {e["name"] for e in xs}
    # learner-side waits and actor-side rollouts on the same timeline
    assert "async.wait_fragments" in names
    assert "actor.rollout" in names


# ---------------------------------------------------------------------------
# live endpoints over a real socket

def test_metrics_endpoint_serves_registry_and_slab_counters():
    registry().counter("updates", tier="jit").inc(3)

    def stats():
        return {"pool": {"workers": {"per_worker": {"steps": [5, 7]},
                                     "total": {"steps": 12}}}}

    with MetricsServer(port=0) as srv:
        srv.add_source("engine", stats)
        code, body = _get(f"{srv.url}/metrics")
        text = body.decode()
    assert code == 200
    assert 'updates{tier="jit"} 3' in text       # registry exposition
    assert ('repro_worker_steps_total{source="engine.pool.workers",'
            'worker="1"} 7') in text


def test_healthz_statuses_and_503_on_dead_worker():
    now = time.time_ns()
    live = {"ok": {"liveness": {"workers": 3, "dead": [],
                                "last_beat_ns": [now, 0, now]}}}
    doc = collect_health([("s", lambda: live)], stale_after_s=10.0)
    assert doc["ok"]
    assert [w["status"] for w in doc["workers"]] == ["ok", "booting", "ok"]

    stale = dict(live)
    stale["ok"] = {"liveness": {"workers": 1, "dead": [],
                                "last_beat_ns": [now - 60_000_000_000]}}
    doc = collect_health([("s", lambda: stale)], stale_after_s=10.0)
    assert doc["ok"]                      # stale labels, never flips
    assert doc["workers"][0]["status"] == "stale"

    dead = {"liveness": {"workers": 2, "dead": [1],
                         "last_beat_ns": [now, now]}}
    with MetricsServer(port=0) as srv:
        srv.add_source("engine", lambda: {"pool": dead})
        code, body = _get(f"{srv.url}/healthz")
    assert code == 503
    doc = json.loads(body)
    assert not doc["ok"]
    assert [w["status"] for w in doc["workers"]] == ["ok", "dead"]


def test_http_404_spans_endpoint_and_idempotent_close(tmp_path):
    telemetry.enable(run_dir=str(tmp_path))
    with telemetry.span("op"):
        pass
    srv = MetricsServer(port=0)
    try:
        code, _ = _get(f"{srv.url}/nope")
        assert code == 404
        code, body = _get(f"{srv.url}/spans")
        assert code == 200
        assert json.loads(body)["op"]["count"] == 1
    finally:
        srv.close()
    srv.close()                           # second close is a no-op
    with pytest.raises(urllib.error.URLError):
        _get(f"{srv.url}/metrics", timeout=1.0)


@pytest.mark.timeout(600)
def test_healthz_flips_when_actor_killed():
    """ISSUE acceptance: /healthz goes 200 -> 503 when an async actor is
    killed mid-run, naming the dead worker."""
    eng = _async_engine()
    spu = 8 * 8
    killed = {"done": False}

    def on_update(u, md):
        if u >= 1 and not killed["done"]:
            eng.rollouts._procs[1].terminate()
            killed["done"] = True

    srv = MetricsServer(port=0)
    srv.add_source("engine", eng.stats)
    try:
        code, body = _get(f"{srv.url}/healthz")
        assert code == 200 and json.loads(body)["ok"]
        hist, _ = eng.run(total_steps=spu * 6, on_update=on_update)
        assert len(hist) == 6
        code, body = _get(f"{srv.url}/healthz")
        assert code == 503
        doc = json.loads(body)
        dead = [w for w in doc["workers"] if w["status"] == "dead"]
        assert [w["worker"] for w in dead] == [1]
        # /metrics keeps serving through the fault, with live slab counters
        code, body = _get(f"{srv.url}/metrics")
        assert code == 200
        assert "repro_worker_steps_total" in body.decode()
    finally:
        srv.close()
        eng.close()


def test_thread_pool_liveness_beats():
    from repro.bridge import wrap
    from repro.envs.ocean_host import HostBandit
    v = wrap(HostBandit, num_envs=2)             # thread backend
    try:
        obs = v.reset(timeout=RECV_T)
        v.step(np.zeros((len(obs), 1), np.int32), timeout=RECV_T)
        live = v.pool.stats()["liveness"]
        assert live["dead"] == []
        assert all(b > 0 for b in live["last_beat_ns"])
        assert len(live["last_beat_ns"]) == 2
    finally:
        v.close()


def test_straggler_monitor_exposes_staleness_age():
    from repro.distributed.fault import StragglerMonitor
    m = StragglerMonitor()
    assert m.age() is None                       # booting, not stale
    m.record(0.01)
    age = m.age()
    assert age is not None and 0 <= age < 5.0
    st = m.stats()
    assert st["samples"] == 1 and st["age_s"] >= age


# ---------------------------------------------------------------------------
# benchwatch: the perf-regression sentinel

def _hist(tmp_path):
    return str(tmp_path / "BENCH_history.jsonl")


def test_benchwatch_appends_schema_versioned_records(tmp_path):
    h = _hist(tmp_path)
    benchwatch.record("demo", {"sps": 100.0}, history=h)
    benchwatch.record("demo", {"sps": 101.0},
                      acceptance={"fast_enough": True}, history=h)
    recs = benchwatch.load_history(h)
    assert len(recs) == 2
    assert all(r["schema"] == benchwatch.SCHEMA for r in recs)
    assert recs[0]["fingerprint"] == benchwatch.fingerprint()
    assert recs[1]["acceptance"] == {"fast_enough": True}
    # a torn tail is skipped, not fatal
    with open(h, "a") as f:
        f.write('{"schema": 1, "bench": "demo"')
    assert len(benchwatch.load_history(h)) == 2


def test_benchwatch_gate_exits_nonzero_on_planted_regression(tmp_path):
    h = _hist(tmp_path)
    benchwatch.record("demo", {"sps": 1000.0}, history=h)
    assert tcli.main(["compare", "--history", h, "--gate"]) == 0  # no base
    benchwatch.record("demo", {"sps": 1020.0}, history=h)
    assert tcli.main(["compare", "--history", h, "--gate"]) == 0  # wiggle
    benchwatch.record("demo", {"sps": 800.0}, history=h)   # -20% planted
    assert tcli.main(["compare", "--history", h, "--gate"]) == 1
    # report-only default never gates
    assert tcli.main(["compare", "--history", h]) == 0
    result = benchwatch.compare(h)
    assert result["benches"]["demo"]["status"] == "regression"
    (reg,) = result["regressions"]
    assert reg["cell"] == "sps" and reg["delta_pct"] < -10


def test_benchwatch_fingerprint_mismatch_never_gates(tmp_path):
    h = _hist(tmp_path)
    rec = benchwatch.record("demo", {"sps": 1000.0}, history=h)
    other = dict(rec, fingerprint={"cores": 9999, "python": "9.9",
                                   "platform": "Other-arch"},
                 cells={"sps": 1.0})             # catastrophic "drop"
    with open(h, "a") as f:
        f.write(json.dumps(other) + "\n")
    result = benchwatch.compare(h)
    assert result["benches"]["demo"]["status"] == "no_baseline"
    assert result["regressions"] == []
    assert tcli.main(["compare", "--history", h, "--gate"]) == 0


def test_benchwatch_baseline_is_rolling_same_fingerprint_median(tmp_path):
    h = _hist(tmp_path)
    for sps in (900.0, 1000.0, 1100.0, 1005.0):
        benchwatch.record("demo", {"sps": sps}, history=h)
    cell = benchwatch.compare(h)["benches"]["demo"]["cells"]["sps"]
    assert cell["baseline"] == 1000.0            # median of first three
    assert cell["status"] == "ok"


# ---------------------------------------------------------------------------
# lint: BLOCKING-NO-TIMEOUT covers accept loops

def test_lint_flags_bare_accept_and_serve_forever():
    from repro.analysis import check_source
    src = ("import socket\n"
           "def serve(sock, httpd):\n"
           "    conn, addr = sock.accept()\n"
           "    httpd.serve_forever()\n")
    rules = {f.rule for f in check_source(src, "m.py")}
    assert "BLOCKING-NO-TIMEOUT" in rules
    fs = [f for f in check_source(src, "m.py")
          if f.rule == "BLOCKING-NO-TIMEOUT"]
    assert len(fs) == 2                          # accept AND serve_forever


def test_lint_http_module_is_clean():
    from repro.analysis import check_file
    path = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                        "repro", "telemetry", "http.py")
    assert [f.rule for f in check_file(path)] == []
