"""HLO cost analyzer: validated against programs with known costs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze

# 1. plain matmul, known flops
def f(a, b): return a @ b
a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
c = jax.jit(f).lower(a, a).compile()
an = analyze(c.as_text(), 1)
exp = 2 * 256**3
assert abs(an["flops"] - exp) / exp < 0.01, (an["flops"], exp)

# 2. scan multiplies body cost by trip count
def g(a):
    def body(x, _): return jnp.tanh(x @ x), None
    x, _ = jax.lax.scan(body, a, None, length=11)
    return x
c = jax.jit(g).lower(a).compile()
an = analyze(c.as_text(), 1)
exp = 11 * 2 * 256**3
assert abs(an["flops"] - exp) / exp < 0.01, (an["flops"], exp)

# 3. sharded: per-device flops + collective accounting
mesh = jax.make_mesh((2, 4), ("data", "model"))
sa = NamedSharding(mesh, P("data", None))
sw = NamedSharding(mesh, P(None, "model"))
def h(x, w):
    y = x @ w                       # local
    return jnp.sum(y.astype(jnp.float32))
x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
with mesh:
    c = jax.jit(h, in_shardings=(sa, sw)).lower(x, w).compile()
an = analyze(c.as_text(), 8)
exp = 2 * 32 * 128 * 16             # per-device
assert abs(an["flops"] - exp) / exp < 0.01, (an["flops"], exp)
assert an["collective_bytes"] > 0   # the final sum all-reduces

# 4. nested scan (scan inside scan) multiplies both trip counts
def nested(a):
    def outer(x, _):
        def inner(y, _): return y @ y, None
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None
    x, _ = jax.lax.scan(outer, a, None, length=5)
    return x
c = jax.jit(nested).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
an = analyze(c.as_text(), 1)
exp = 15 * 2 * 128**3
assert abs(an["flops"] - exp) / exp < 0.01, (an["flops"], exp)
print("HLO_ANALYSIS_OK")
"""


def test_hlo_analysis_known_costs():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env, cwd=ROOT)
    assert "HLO_ANALYSIS_OK" in out.stdout, out.stderr[-3000:]


def test_parser_handles_comments_and_tuples():
    from repro.launch.hlo_analysis import parse
    text = """
HloModule test

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c = s32[] constant(1)
  %j = s32[] add(%i, %c)
  ROOT %t = (s32[], f32[4,4]) tuple(%j, %d)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[4,4]) -> (s32[], /*index=1*/f32[4,4]) {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  ROOT %w = (s32[], /*index=1*/f32[4,4]) while(%t0), condition=%cond, body=%body
}
"""
    from repro.launch.hlo_analysis import analyze
    an = analyze(text, 1)
    assert an["flops"] == 9 * 2 * 4 * 4 * 4, an["flops"]
    # byte traffic is trip-weighted too: 96 B of dot traffic per iteration
    # (tuple plumbing fuses away), times the 9 loop trips
    assert an["bytes"] == 9 * 96, an["bytes"]


def test_input_output_alias_header_parsing():
    from repro.launch.hlo_analysis import donated_params, input_output_aliases
    text = ("HloModule jit_step, is_scheduled=true, "
            "input_output_alias={ {0}: (0, {}, may-alias), "
            "{1}: (3, {}, must-alias), {2, 0}: (5, {1}, may-alias) }, "
            "entry_computation_layout={(f32[8]{0})->f32[8]{0}}\n\n"
            "ENTRY %main () -> f32[] {\n"
            "  ROOT %c = f32[] constant(0)\n"
            "}\n")
    aliases = input_output_aliases(text)
    assert aliases[(0,)] == (0, (), "may-alias")
    assert aliases[(1,)] == (3, (), "must-alias")
    assert aliases[(2, 0)] == (5, (1,), "may-alias")
    assert donated_params(text) == {0, 3, 5}


def test_input_output_alias_absent():
    from repro.launch.hlo_analysis import donated_params, input_output_aliases
    text = "HloModule plain\n\nENTRY %m () -> f32[] { ROOT %c = f32[] constant(0) }\n"
    assert input_output_aliases(text) == {}
    assert donated_params(text) == set()


def test_donated_params_on_real_compiled_module():
    """XLA's own post-optimization text must satisfy the parser: a donated
    elementwise update aliases param 0, a donated reduction aliases nothing."""
    import jax
    import jax.numpy as jnp
    import warnings
    from repro.launch.hlo_analysis import donated_params

    x = jnp.ones((64,), jnp.float32)
    hlo = jax.jit(lambda a: a + 1.0,
                  donate_argnums=(0,)).lower(x).compile().as_text()
    assert 0 in donated_params(hlo)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # XLA warns: donation unused
        hlo = jax.jit(lambda a: jnp.sum(a),
                      donate_argnums=(0,)).lower(x).compile().as_text()
    assert 0 not in donated_params(hlo)
