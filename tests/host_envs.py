"""Module-level helper host envs for the HostBridge tests.

These live outside ``test_host_bridge.py`` so the ``backend="proc"`` suites
can pickle them into spawn workers by reference: a worker then imports only
this module (numpy + ``repro.core.spaces``, both jax-free) instead of the
test module, which imports jax at the top and would add seconds of startup
per worker process.
"""
import time

import numpy as np

from repro.core import spaces as sp


class SlowEnv:
    """Duck env whose step blocks long enough to trip small timeouts."""

    def __init__(self, step_s: float = 30.0):
        self.step_s = step_s
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(2)

    def reset(self, seed):
        return np.zeros(1, np.float32)

    def step(self, a):
        time.sleep(self.step_s)
        return np.zeros(1, np.float32), 0.0, False, {}


class CrashyEnv:
    """Duck env that raises on the k-th step (or on reset)."""

    def __init__(self, crash_step: int = 3, crash_reset: bool = False):
        self.crash_step, self.crash_reset = crash_step, crash_reset
        self.observation_space = sp.Box((1,))
        self.action_space = sp.Discrete(2)
        self.t = 0

    def reset(self, seed):
        if self.crash_reset:
            raise RuntimeError("reset kaboom")
        self.t = 0
        return np.zeros(1, np.float32)

    def step(self, a):
        self.t += 1
        if self.t >= self.crash_step:
            raise RuntimeError("step kaboom")
        return np.zeros(1, np.float32), 1.0, False, {}


class JitterEnv:
    """Duck env with lognormal step latency (first-finisher tests)."""

    def __init__(self, mean_ms=0.5, seed=0, horizon=64):
        self.observation_space = sp.Box((2,))
        self.action_space = sp.Discrete(2)
        self.rng = np.random.RandomState(seed)
        self.mean_ms, self.horizon, self.t = mean_ms, horizon, 0

    def reset(self, seed):
        self.t = 0
        return np.zeros(2, np.float32)

    def step(self, a):
        time.sleep(self.rng.lognormal(np.log(self.mean_ms), 0.6) / 1e3)
        self.t += 1
        done = self.t >= self.horizon
        return np.zeros(2, np.float32), 0.0, done, {}
