"""End-to-end serving driver: batched autoregressive decoding with a KV/SSM
cache — prefill a batch of prompts, then stream tokens (serve_step), the
program the decode_* dry-run shapes lower at 256-chip scale.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen3-0.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.policy import BackbonePolicy
from repro.rl import actor

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--tokens", type=int, default=32)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
policy = BackbonePolicy(cfg, tp=1)
key = jax.random.PRNGKey(0)
params = policy.init(key)

# a batch of requests (random token prompts stand in for real ones)
prompts = jax.random.randint(jax.random.fold_in(key, 1),
                             (args.batch, args.prompt_len), 0, cfg.vocab_size)
max_len = args.prompt_len + args.tokens

prefill = jax.jit(actor.make_prefill_step(policy, max_len))
serve = jax.jit(actor.make_serve_step(policy), donate_argnums=(2,))

tok, value, caches = prefill(params, {"tokens": prompts},
                             jax.random.fold_in(key, 2))
jax.block_until_ready(tok)
t0 = time.perf_counter()
out = [tok]
for i in range(args.tokens - 1):
    tok, value, caches = serve(params, tok, caches, jax.random.fold_in(key, 3 + i))
    out.append(tok)
seq = jnp.concatenate(out, axis=1)
jax.block_until_ready(seq)
dt = time.perf_counter() - t0
print(f"{cfg.name}: batch={args.batch} generated {seq.shape[1]} tokens each")
print(f"throughput: {args.batch * (args.tokens - 1) / dt:.1f} tok/s "
      f"(steady-state decode, CPU)")
print("sample:", seq[0, :16].tolist())
