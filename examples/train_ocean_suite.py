"""End-to-end driver: train the whole Ocean suite (paper §4 + Ocean II) —
every env solved >0.9 with its committed preset, under a coffee break total.

  PYTHONPATH=src python examples/train_ocean_suite.py
"""
import time

from repro.configs.ocean import ocean_tcfg, preset
from repro.envs.ocean import OCEAN
from repro.rl.trainer import Trainer

t_all = time.perf_counter()
results = {}
for name, cls in OCEAN.items():
    t0 = time.perf_counter()
    p = preset(name)
    tr = Trainer(cls(), ocean_tcfg(name, updates_per_launch=4),
                 hidden=p.hidden, recurrent=p.recurrent, conv=p.conv)
    m = tr.train(p.total_steps, target_score=p.target_score)
    results[name] = m
    print(f"{name:12s} {'SOLVED' if m['score'] >= 0.9 else 'FAILED':6s} "
          f"score={m['score']:.3f} steps={m['env_steps']:7d} "
          f"({time.perf_counter() - t0:.0f}s)")
n = sum(m["score"] >= 0.9 for m in results.values())
print(f"\n{n}/{len(results)} solved in {time.perf_counter() - t_all:.0f}s")
