"""End-to-end driver: train the whole Ocean suite (paper §4) — every env
solved >0.9 with one barely-tuned config, under a coffee break total.

  PYTHONPATH=src python examples/train_ocean_suite.py
"""
import time

from repro.configs.base import TrainConfig
from repro.envs.ocean import OCEAN
from repro.rl.trainer import Trainer

TCFG = TrainConfig(num_envs=64, unroll_length=64, update_epochs=4,
                   num_minibatches=4, learning_rate=1e-3, gamma=0.95)
BUDGET = {"squared": 300_000, "password": 300_000, "stochastic": 200_000,
          "memory": 500_000, "multiagent": 150_000, "spaces": 200_000,
          "bandit": 150_000, "continuous": 400_000}

t_all = time.perf_counter()
results = {}
for name, cls in OCEAN.items():
    t0 = time.perf_counter()
    tr = Trainer(cls(), TCFG, hidden=64, recurrent=(name == "memory"))
    m = tr.train(BUDGET[name], target_score=0.9)
    results[name] = m
    print(f"{name:12s} {'SOLVED' if m['score'] >= 0.9 else 'FAILED':6s} "
          f"score={m['score']:.3f} steps={m['env_steps']:7d} "
          f"({time.perf_counter() - t0:.0f}s)")
n = sum(m["score"] >= 0.9 for m in results.values())
print(f"\n{n}/{len(results)} solved in {time.perf_counter() - t_all:.0f}s")
