"""End-to-end driver: train the whole Ocean suite (paper §4 + Ocean II +
the league's duel) — every env solved >0.9 with its committed preset, under
a coffee break total. Competitive envs train under league self-play and
their solved criterion is winrate vs the random baseline (self-play score
is pinned near 0.5 by the zero-sum symmetry).

  PYTHONPATH=src python examples/train_ocean_suite.py
"""
import tempfile
import time

from repro.configs.ocean import ocean_tcfg, preset
from repro.envs.ocean import OCEAN
from repro.league import run_selfplay
from repro.rl.trainer import Trainer

SELFPLAY = ("duel",)                 # competitive envs: league self-play

t_all = time.perf_counter()
results = {}
for name, cls in OCEAN.items():
    t0 = time.perf_counter()
    p = preset(name)
    if name in SELFPLAY:
        with tempfile.TemporaryDirectory() as d:
            res = run_selfplay(cls(), ocean_tcfg(name, updates_per_launch=4),
                               league_dir=d, total_steps=p.total_steps,
                               snapshot_every=8, hidden=p.hidden,
                               recurrent=p.recurrent)
        score = res.winrate_random
    else:
        tr = Trainer(cls(), ocean_tcfg(name, updates_per_launch=4),
                     hidden=p.hidden, recurrent=p.recurrent, conv=p.conv)
        score = tr.train(p.total_steps, target_score=p.target_score)["score"]
    results[name] = score
    crit = "winrate" if name in SELFPLAY else "score"
    print(f"{name:12s} {'SOLVED' if score >= 0.9 else 'FAILED':6s} "
          f"{crit}={score:.3f} ({time.perf_counter() - t0:.0f}s)")
n = sum(s >= 0.9 for s in results.values())
print(f"\n{n}/{len(results)} solved in {time.perf_counter() - t_all:.0f}s")
