"""Quickstart: the paper's pitch in 30 lines.

A structured env (nested Dict obs + Dict actions) becomes Atari-shaped with
one wrapper; a stock PPO trains it; the model unflattens in its first line.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Emulated
from repro.envs.ocean import Spaces
from repro.rl.trainer import Trainer
from repro.configs.base import TrainConfig

# 1. one-line wrapper: structured env -> flat Box obs + MultiDiscrete action
env = Emulated(Spaces())
print("obs space:", env.observation_space)          # Box((13,))
print("action space:", env.action_space)            # MultiDiscrete((2, 2))

# 2. the exact inverse is available for your model's first line
state = env.init(jax.random.PRNGKey(0))
state, obs = env.reset(state, jax.random.PRNGKey(1))
print("unflattened:", {k: v.shape for k, v in env.unemulate_obs(obs).items()})

# 3. stock PPO + MLP solves it (score > 0.9), coffee-break scale
trainer = Trainer(Spaces(), TrainConfig(num_envs=64, unroll_length=64,
                                        update_epochs=4, num_minibatches=4,
                                        learning_rate=1e-3, gamma=0.95),
                  hidden=64)
m = trainer.train(150_000, log_every=10, target_score=0.9)
print(f"solved={m['score'] >= 0.9} score={m['score']:.3f} "
      f"steps={m['env_steps']}")
