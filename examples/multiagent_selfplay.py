"""Multiagent training through emulation: canonical agent ordering + a shared
policy — the paper's Neural MMO competition pattern in miniature.

  PYTHONPATH=src python examples/multiagent_selfplay.py
"""
from repro.configs.base import TrainConfig
from repro.envs.ocean import Multiagent
from repro.rl.trainer import Trainer

trainer = Trainer(Multiagent(), TrainConfig(num_envs=64, unroll_length=64,
                                            update_epochs=4,
                                            num_minibatches=4,
                                            learning_rate=1e-3, gamma=0.95),
                  hidden=64)
# one shared policy controls both agents; the env pays agent i only for
# action i, so any scramble of the agent ordering caps the score at 0.5
m = trainer.train(150_000, log_every=10, target_score=0.9)
assert m["score"] >= 0.9, m
print(f"selfplay solved: score={m['score']:.3f} — agent ordering intact")
